"""The shard-aware kernel path: one GMRES cycle, local and distributed.

Everything here drives ``gmres_sharded`` / ``gmres_sstep_sharded`` — thin
shard_map wrappers over the SAME cycle the single-device solver runs —
and asserts three things:

  1. parity: sharded solves match single-device solves to tolerance on
     dense / ELL / banded operators, at every shard count the running
     process can host (the hypothesis PROPERTY version lives in
     tests/test_properties.py with the other hypothesis suites);
  2. dispatch: the split-phase CGS2 pair, the halo SpMV kernels and the
     CA matrix-powers kernel actually ENGAGE under shard_map
     (spy-verified), and a forced VMEM overflow degrades to the
     psum-safe reference with identical results;
  3. multi-shard for real: the main pytest process usually sees ONE cpu
     device (1-shard meshes — the wrappers, contexts and collectives all
     still execute), so one subprocess with 4 fake host devices pins
     4-way parity for all operator formats.  CI additionally runs this
     whole module under XLA_FLAGS=--xla_force_host_platform_device_count=4,
     where the in-process tests sweep 1/2/4-shard meshes directly.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import (gmres, gmres_sharded, gmres_sstep,
                        gmres_sstep_sharded, operators, stencils)
from repro.core.distributed import shard_specs
from repro.kernels import tuning

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# 1 in the plain tier-1 run; 1/2/4 when the process hosts 4 fake devices
# (the CI distributed step) — the parametrized sweeps adapt automatically.
SHARDS = [p for p in (1, 2, 4) if p <= jax.device_count()]


def _mesh(p):
    return make_mesh((p,), ("rows",))


def _system(fmt, nx, key):
    """(operator, b) for a small convergent system; n = nx * nx."""
    n = nx * nx
    if fmt == "dense":
        a = operators.random_diagdom(jax.random.PRNGKey(key), n)
        op = operators.DenseOperator(a, backend="pallas")
    elif fmt == "banded":
        op = stencils.poisson_2d(nx, nx, backend="pallas")
    elif fmt == "ell":
        op = stencils.poisson_2d(nx, nx, backend="pallas").to_ell()
    else:
        raise ValueError(fmt)
    b = jax.random.normal(jax.random.PRNGKey(key + 1), (n,))
    return op, b


def _assert_parity(res_sharded, res_single, a_dense, b, rtol=2e-3):
    assert bool(res_sharded.converged)
    bn = float(jnp.linalg.norm(b))
    rel = float(jnp.linalg.norm(a_dense @ res_sharded.x - b)) / bn
    assert rel < 5e-5, rel
    err = (float(jnp.linalg.norm(res_sharded.x - res_single.x))
           / max(float(jnp.linalg.norm(res_single.x)), 1e-30))
    assert err < rtol, err


# --------------------------------------------------------------------------
# parity: sharded == single-device, per format and shard count
# --------------------------------------------------------------------------
@pytest.mark.parametrize("p", SHARDS)
@pytest.mark.parametrize("fmt", ["dense", "ell", "banded"])
def test_sharded_matches_single(fmt, p):
    op, b = _system(fmt, 8, key=0)
    res_s = gmres(op, b, m=16, tol=1e-5, max_restarts=100)
    res_d = gmres_sharded(_mesh(p), "rows", op, b, m=16, tol=1e-5,
                          max_restarts=100)
    a_dense = op.a if fmt == "dense" else op.todense()
    _assert_parity(res_d, res_s, a_dense, b)


@pytest.mark.parametrize("p", SHARDS)
def test_sstep_sharded_matches_single(p):
    op, b = _system("banded", 10, key=4)
    res_s = gmres_sstep(op, b, s=4, blocks=5, tol=1e-5, max_restarts=60)
    res_d = gmres_sstep_sharded(_mesh(p), "rows", op, b, s=4, blocks=5,
                                tol=1e-5, max_restarts=60)
    _assert_parity(res_d, res_s, op.todense(), b)


def test_sstep_sharded_scale_invariant_through_ca_kernel():
    """The CA powers path must survive ANY system scale (PR 3 contract).

    Deferred normalization computes raw ||A||^j-sized powers; without the
    theta pre-scaling in sstep._make_block_fns, bands scaled by 1e4 at
    s=8 overflow f32 and the solve returns NaN.  A 1-shard mesh
    guarantees s*halo <= n_local so the CA kernel genuinely engages.
    """
    base = stencils.poisson_2d(16, 16, backend="pallas")
    n = 256
    for c in (1e4, 1e-4):
        op = operators.BandedOperator(base.bands * c, base.offsets,
                                      "pallas")
        b = jnp.sin(jnp.arange(n) * 0.37) * c
        ref = gmres_sstep(op, b, s=8, blocks=2, tol=1e-4, max_restarts=60)
        sh = gmres_sstep_sharded(_mesh(1), "rows", op, b, s=8, blocks=2,
                                 tol=1e-4, max_restarts=60)
        assert bool(jnp.isfinite(sh.x).all()), f"NaN at scale {c}"
        assert bool(sh.converged) == bool(ref.converged)
        err = (float(jnp.linalg.norm(sh.x - ref.x))
               / max(float(jnp.linalg.norm(ref.x)), 1e-30))
        assert err < 2e-3, (c, err)


def test_sharded_compute_dtype_bf16_converges():
    """The sharded split-phase path composes with bf16 basis storage."""
    op, b = _system("banded", 8, key=6)
    res = gmres_sharded(_mesh(SHARDS[-1]), "rows", op, b, m=16, tol=1e-4,
                        max_restarts=200, compute_dtype=jnp.bfloat16)
    assert bool(res.converged)
    rel = float(jnp.linalg.norm(op.todense() @ res.x - b)
                / jnp.linalg.norm(b))
    assert rel < 5e-4


def test_sparse_without_halo_bound_falls_back_to_gather():
    """halo=None (unknown structure) must stay correct via all-gather."""
    op, b = _system("ell", 8, key=8)
    blind = operators.SparseOperator(op.values, op.cols, backend="pallas",
                                     halo=None)
    res_s = gmres(blind, b, m=16, tol=1e-5, max_restarts=100)
    res_d = gmres_sharded(_mesh(SHARDS[-1]), "rows", blind, b, m=16,
                          tol=1e-5, max_restarts=100)
    _assert_parity(res_d, res_s, op.todense(), b)


def test_shard_specs_rejects_matrix_free():
    fn = operators.FunctionOperator(lambda v: v, 8)
    with pytest.raises(TypeError):
        shard_specs(fn, "rows")


# --------------------------------------------------------------------------
# dispatch: the sharded solve must actually HIT the per-shard kernels
# --------------------------------------------------------------------------
def _spy(monkeypatch, mod, name, calls):
    orig = getattr(mod, name)

    def wrapper(*args, **kw):
        calls[name] = calls.get(name, 0) + 1
        return orig(*args, **kw)

    monkeypatch.setattr(mod, name, wrapper)


def test_sharded_dispatch_hits_split_phase_cgs2(monkeypatch):
    import repro.kernels.cgs2 as cgs2_mod

    calls = {}
    _spy(monkeypatch, cgs2_mod, "gs_project_partial", calls)
    _spy(monkeypatch, cgs2_mod, "gs_update", calls)
    op, b = _system("banded", 8, key=20)
    res = gmres_sharded(_mesh(SHARDS[-1]), "rows", op, b, m=12, tol=1e-5,
                        max_restarts=100)
    assert bool(res.converged)
    assert calls.get("gs_project_partial", 0) > 0, \
        "split-phase project kernel never engaged in the sharded solve"
    assert calls.get("gs_update", 0) > 0, \
        "split-phase update kernel never engaged in the sharded solve"


def test_sharded_dispatch_hits_halo_spmv(monkeypatch):
    import repro.kernels.spmv as spmv_mod

    calls = {}
    _spy(monkeypatch, spmv_mod, "banded_matvec_halo", calls)
    _spy(monkeypatch, spmv_mod, "ell_matvec_halo", calls)
    mesh = _mesh(SHARDS[-1])
    op, b = _system("banded", 8, key=22)
    gmres_sharded(mesh, "rows", op, b, m=12, tol=1e-5, max_restarts=100)
    gmres_sharded(mesh, "rows", op.to_ell(), b, m=12, tol=1e-5,
                  max_restarts=100)
    assert calls.get("banded_matvec_halo", 0) > 0, \
        "banded halo kernel never engaged"
    assert calls.get("ell_matvec_halo", 0) > 0, \
        "ELL halo kernel never engaged"


def test_sstep_sharded_dispatch_hits_ca_kernels(monkeypatch):
    import repro.kernels.block_gs as bg_mod
    import repro.kernels.matrix_powers as mp_mod

    calls = {}
    _spy(monkeypatch, mp_mod, "banded_powers_halo", calls)
    _spy(monkeypatch, bg_mod, "block_gs_project", calls)
    _spy(monkeypatch, bg_mod, "block_gs_update", calls)
    op, b = _system("banded", 8, key=24)
    res = gmres_sstep_sharded(_mesh(SHARDS[-1]), "rows", op, b, s=2,
                              blocks=4, tol=1e-5, max_restarts=40)
    assert bool(res.converged)
    for name in ("banded_powers_halo", "block_gs_project",
                 "block_gs_update"):
        assert calls.get(name, 0) > 0, f"{name} never engaged"


def test_sharded_forced_overflow_falls_back(monkeypatch):
    """fits forced False: the halo REFERENCE must carry the solve, with
    the same answer (the silent-degrade contract, sharded edition)."""
    op, b = _system("banded", 8, key=26)
    mesh = _mesh(SHARDS[-1])
    res_kernel = gmres_sharded(mesh, "rows", op, b, m=12, tol=1e-5,
                               max_restarts=100)

    import repro.kernels.spmv as spmv_mod

    def boom(*a, **k):
        raise AssertionError("kernel path taken despite forced overflow")

    monkeypatch.setattr(tuning, "banded_fits", lambda *a, **k: False)
    monkeypatch.setattr(spmv_mod, "banded_matvec_halo", boom)
    res_ref = gmres_sharded(mesh, "rows", op, b, m=12, tol=1e-5,
                            max_restarts=100)
    assert bool(res_ref.converged)
    np.testing.assert_allclose(np.asarray(res_ref.x),
                               np.asarray(res_kernel.x),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# multi-shard for real: 4 fake host devices in a subprocess
# --------------------------------------------------------------------------
def test_sharded_parity_4dev_subprocess():
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import (gmres, gmres_sharded, gmres_sstep,
                                gmres_sstep_sharded, operators, stencils)
        mesh = make_mesh((4,), ('rows',))
        out = {}
        b = jax.random.normal(jax.random.PRNGKey(1), (144,))
        banded = stencils.poisson_2d(12, 12, backend='pallas')
        cases = {
            'dense': operators.DenseOperator(
                operators.random_diagdom(jax.random.PRNGKey(0), 144),
                backend='pallas'),
            'banded': banded,
            'ell': banded.to_ell(),
        }
        for fmt, op in cases.items():
            ref = gmres(op, b, m=16, tol=1e-5, max_restarts=150)
            sh = gmres_sharded(mesh, 'rows', op, b, m=16, tol=1e-5,
                               max_restarts=150)
            out[fmt] = {
                'conv': bool(sh.converged),
                'err': float(jnp.linalg.norm(sh.x - ref.x)
                             / jnp.linalg.norm(ref.x)),
            }
        ref = gmres_sstep(banded, b, s=4, blocks=5, tol=1e-5,
                          max_restarts=60)
        sh = gmres_sstep_sharded(mesh, 'rows', banded, b, s=4, blocks=5,
                                 tol=1e-5, max_restarts=60)
        out['sstep_banded'] = {
            'conv': bool(sh.converged),
            'err': float(jnp.linalg.norm(sh.x - ref.x)
                         / jnp.linalg.norm(ref.x)),
        }
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for fmt, r in out.items():
        assert r["conv"], (fmt, r)
        assert r["err"] < 2e-3, (fmt, r)
