"""Sparse/structured operator subsystem vs dense reference (Pallas interpret).

Mirrors tests/test_fused_solver.py for the SpMV layer: the ELL gather
kernel and the banded stencil kernel (kernels/spmv.py), the
``SparseOperator`` / ``BandedOperator`` dispatch (core/operators.py), the
stencil constructors (core/stencils.py), and the solver end-to-end —
``gmres`` / ``gmres_batched`` on 2-D/3-D Poisson and convection-diffusion
through ``backend="pallas"``.  On CPU ``kernels.tuning.kernel_mode()``
returns "interpret", so every kernel assertion here exercises the REAL
kernel arithmetic through the Pallas interpreter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_batched, stencils
from repro.core.operators import (BandedOperator, DenseOperator,
                                  SparseOperator)
from repro.kernels import spmv, tuning

KEY = jax.random.PRNGKey(0)


def _random_ell(n, width, seed=0, dtype=jnp.float32):
    values = jax.random.normal(jax.random.PRNGKey(seed), (n, width),
                               ).astype(dtype)
    cols = jax.random.randint(jax.random.PRNGKey(seed + 1), (n, width), 0, n)
    return values, cols.astype(jnp.int32)


def relres(a, x, b):
    return float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))


# --------------------------------------------------------------------------
# ELL gather kernel vs the jnp oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,width,bm", [
    (256, 5, 128),
    (300, 7, 128),      # padding path (n not a block multiple)
    (96, 3, 256),       # block larger than the matrix
])
def test_ell_kernel_matches_reference(n, width, bm):
    values, cols = _random_ell(n, width)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    y_k = spmv.ell_matvec(values, cols, x, block_m=bm, interpret=True)
    y_r = spmv.ell_matvec_ref(values, cols, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-5, atol=3e-5)


def test_ell_kernel_multi_rhs():
    values, cols = _random_ell(200, 4, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(5), (200, 6))
    y_k = spmv.ell_matvec(values, cols, x, block_m=64, interpret=True)
    y_r = spmv.ell_matvec_ref(values, cols, x)
    assert y_k.shape == (200, 6)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-5, atol=3e-5)


def test_ell_kernel_bf16_values():
    """bf16 matrix storage, f32 operand: f32 accumulation in-kernel."""
    values, cols = _random_ell(160, 5, seed=7, dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(9), (160,))
    y_k = spmv.ell_matvec(values, cols, x, block_m=64, interpret=True)
    y_r = spmv.ell_matvec_ref(values, cols, x)
    assert y_k.dtype == jnp.float32         # promoted, matches dense a @ x
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-2, atol=2e-2)


def test_ell_kernel_validates_shapes():
    values, cols = _random_ell(64, 3)
    with pytest.raises(TypeError):
        spmv.ell_matvec(values, cols, jnp.zeros((65,)), interpret=True)
    with pytest.raises(TypeError):
        spmv.ell_matvec(values, cols[:32], jnp.zeros((64,)), interpret=True)


# --------------------------------------------------------------------------
# banded/stencil kernel vs the jnp oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,offsets,bm", [
    (256, (-16, -1, 0, 1, 16), 128),
    (300, (-20, -1, 0, 1, 20), 128),    # padding path
    (90, (-30, -9, -1, 0, 1, 9, 30), 128),  # 7-band, block > n
])
def test_banded_kernel_matches_reference(n, offsets, bm):
    bands = jax.random.normal(KEY, (len(offsets), n))
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    y_k = spmv.banded_matvec(bands, x, offsets, block_m=bm, interpret=True)
    y_r = spmv.banded_matvec_ref(bands, x, offsets)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-5, atol=3e-5)


def test_banded_kernel_multi_rhs():
    offsets = (-8, -1, 0, 1, 8)
    bands = jax.random.normal(KEY, (5, 128))
    x = jax.random.normal(jax.random.PRNGKey(3), (128, 4))
    y_k = spmv.banded_matvec(bands, x, offsets, block_m=64, interpret=True)
    y_r = spmv.banded_matvec_ref(bands, x, offsets)
    assert y_k.shape == (128, 4)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-5, atol=3e-5)


def test_banded_kernel_validates_shapes():
    bands = jnp.ones((3, 64))
    with pytest.raises(TypeError):
        spmv.banded_matvec(bands, jnp.zeros((64,)), (-1, 0), interpret=True)
    with pytest.raises(TypeError):
        spmv.banded_matvec(bands, jnp.zeros((60,)), (-1, 0, 1),
                           interpret=True)


# --------------------------------------------------------------------------
# operators: matvec parity vs dense materialization, both backends
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_sparse_operator_matches_dense(backend):
    a = np.array(jax.random.normal(KEY, (150, 150)))
    a[np.abs(a) < 1.2] = 0.0               # sparsify, ragged row widths
    op = SparseOperator.from_dense(a, backend=backend)
    dense = np.asarray(op.todense())
    np.testing.assert_allclose(dense, a, rtol=1e-6, atol=1e-6)
    v = jax.random.normal(jax.random.PRNGKey(2), (150,))
    np.testing.assert_allclose(np.asarray(op(v)), a @ np.asarray(v),
                               rtol=3e-5, atol=3e-5)
    x = jax.random.normal(jax.random.PRNGKey(3), (150, 5))
    np.testing.assert_allclose(np.asarray(op(x)), a @ np.asarray(x),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_banded_operator_matches_dense(backend):
    op = stencils.convection_diffusion_2d(13, 11, beta=(0.7, 0.3),
                                          backend=backend)
    a = np.asarray(op.todense())
    v = jax.random.normal(jax.random.PRNGKey(4), (143,))
    np.testing.assert_allclose(np.asarray(op(v)), a @ np.asarray(v),
                               rtol=3e-5, atol=3e-5)
    x = jax.random.normal(jax.random.PRNGKey(5), (143, 3))
    np.testing.assert_allclose(np.asarray(op(x)), a @ np.asarray(x),
                               rtol=3e-5, atol=3e-5)


def test_banded_to_ell_same_matrix():
    band = stencils.poisson_2d(7, 9)
    ell = band.to_ell()
    np.testing.assert_allclose(np.asarray(band.todense()),
                               np.asarray(ell.todense()), atol=0)
    v = jax.random.normal(KEY, (63,))
    np.testing.assert_allclose(np.asarray(band(v)), np.asarray(ell(v)),
                               rtol=3e-5, atol=3e-5)


def test_from_dense_rejects_lossy_width():
    a = np.eye(8, dtype=np.float32)
    a[0, :] = 1.0                          # one row with 8 nonzeros
    with pytest.raises(ValueError):
        SparseOperator.from_dense(a, width=3)


def test_operator_pytrees_survive_roundtrip():
    sp = stencils.poisson_2d(6, fmt="ell", backend="pallas")
    leaves, treedef = jax.tree_util.tree_flatten(sp)
    sp2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert sp2.backend == "pallas"
    bd = stencils.poisson_2d(6, backend="pallas")
    leaves, treedef = jax.tree_util.tree_flatten(bd)
    bd2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert bd2.offsets == (-6, -1, 0, 1, 6) and bd2.backend == "pallas"


# --------------------------------------------------------------------------
# stencil constructors
# --------------------------------------------------------------------------
def test_poisson_2d_structure():
    nx, ny = 5, 4
    a = np.asarray(stencils.poisson_2d(nx, ny).todense())
    ref = np.zeros_like(a)
    for iy in range(ny):
        for ix in range(nx):
            i = ix + nx * iy
            ref[i, i] = 4
            for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                jx, jy = ix + di, iy + dj
                if 0 <= jx < nx and 0 <= jy < ny:
                    ref[i, jx + nx * jy] = -1
    np.testing.assert_allclose(a, ref, atol=0)


def test_poisson_3d_structure():
    nx, ny, nz = 3, 4, 3
    a = np.asarray(stencils.poisson_3d(nx, ny, nz).todense())
    np.testing.assert_allclose(a, a.T, atol=0)           # SPD stencil
    assert a.shape == (36, 36) and a[0, 0] == 6
    # interior row touches exactly 7 entries
    i = 1 + nx * (1 + ny * 1)
    assert int((a[i] != 0).sum()) == 7


def test_convection_diffusion_reduces_to_poisson():
    cd = stencils.convection_diffusion_2d(6, 5, beta=(0.0, 0.0))
    po = stencils.poisson_2d(6, 5)
    np.testing.assert_allclose(np.asarray(cd.todense()),
                               np.asarray(po.todense()), atol=0)
    a = np.asarray(stencils.convection_diffusion_2d(6, 5,
                                                    beta=(0.8, 0.2)).todense())
    assert np.abs(a - a.T).max() > 0       # convection breaks symmetry


# --------------------------------------------------------------------------
# solver end-to-end: sparse systems through the kernel path
# --------------------------------------------------------------------------
def test_gmres_sparse_poisson_pallas_converges():
    """The acceptance-criteria solve: 2-D Poisson, ELL, Pallas SpMV path."""
    op = stencils.poisson_2d(12, 12, fmt="ell", backend="pallas")
    b = jax.random.normal(jax.random.PRNGKey(1), (144,))
    res = gmres(op, b, m=30, tol=1e-6, max_restarts=200)
    assert bool(res.converged)
    a = op.todense()
    assert relres(a, res.x, b) < 5e-6
    # parity vs the jnp-reference sparse path AND the dense solve
    res_ref = gmres(stencils.poisson_2d(12, 12, fmt="ell"), b, m=30,
                    tol=1e-6, max_restarts=200)
    res_dense = gmres(a, b, m=30, tol=1e-6, max_restarts=200)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(res_ref.x),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(res_dense.x),
                               rtol=1e-4, atol=1e-5)


def test_gmres_banded_convection_diffusion_converges():
    op = stencils.convection_diffusion_2d(10, 10, beta=(0.6, 0.3),
                                          backend="pallas")
    b = jnp.ones((100,))
    res = gmres(op, b, m=30, tol=1e-6, max_restarts=200)
    assert bool(res.converged)
    assert relres(op.todense(), res.x, b) < 5e-6


def test_gmres_sparse_under_jit():
    op = stencils.poisson_2d(8, 8, fmt="ell", backend="pallas")
    b = jax.random.normal(jax.random.PRNGKey(3), (64,))
    res = jax.jit(lambda o, b: gmres(o, b, m=20, tol=1e-5,
                                     max_restarts=100))(op, b)
    assert bool(res.converged)


def test_gmres_fused_scheme_degrades_with_sparse_operator():
    """gs="fused" needs a DenseOperator; sparse degrades to cgs2_fused."""
    op = stencils.poisson_2d(8, 8, backend="pallas")
    b = jax.random.normal(jax.random.PRNGKey(5), (64,))
    res = gmres(op, b, m=20, tol=1e-5, max_restarts=100, gs="fused")
    assert bool(res.converged)
    assert relres(op.todense(), res.x, b) < 5e-5


def test_gmres_batched_sparse_matches_per_lane():
    op = stencils.poisson_2d(9, 9, fmt="ell", backend="pallas")
    bs = jax.random.normal(jax.random.PRNGKey(7), (3, 81))
    res = gmres_batched(op, bs, m=20, tol=1e-5, max_restarts=100)
    assert bool(res.converged.all())
    for i in range(3):
        single = gmres(op, bs[i], m=20, tol=1e-5, max_restarts=100)
        np.testing.assert_allclose(np.asarray(res.x[i]),
                                   np.asarray(single.x),
                                   rtol=1e-4, atol=1e-5)


def test_gmres_sparse_compute_dtype_bf16():
    op = stencils.poisson_2d(10, 10, fmt="ell", backend="pallas")
    b = jax.random.normal(jax.random.PRNGKey(9), (100,))
    res = gmres(op, b, m=25, tol=1e-4, max_restarts=200,
                compute_dtype=jnp.bfloat16)
    assert bool(res.converged)
    assert relres(op.todense(), res.x, b) < 5e-4


def test_sparse_operator_ref_env_override(monkeypatch):
    """REPRO_KERNELS=ref must force the jnp path (identical results)."""
    op = stencils.poisson_2d(6, 6, fmt="ell", backend="pallas")
    v = jax.random.normal(KEY, (36,))
    y_kernel = np.asarray(op(v))
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    y_ref = np.asarray(op(v))
    np.testing.assert_allclose(y_kernel, y_ref, rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# tuning
# --------------------------------------------------------------------------
def test_choose_spmv_block_respects_budget():
    for (n, width, k) in [(1024, 5, 1), (65536, 7, 1), (16384, 9, 8)]:
        bm = tuning.choose_spmv_block(n, width, "float32", k=k)
        resident = tuning._round_up(n, tuning.LANE) * k * 4
        assert 2 * bm * width * 8 + resident + bm * k * 4 <= tuning.VMEM_BUDGET
        assert bm % tuning.sublane("float32") == 0 or bm >= n


def test_spmv_fits_rejects_vmem_overflow():
    assert tuning.spmv_fits(65536, 5, jnp.float32)
    # an operand too large to sit in VMEM must push the op to the jnp path
    assert not tuning.spmv_fits(8_000_000, 5, jnp.float32)
    assert tuning.banded_fits(65536, 5, jnp.float32, halo=256)
    assert not tuning.banded_fits(8_000_000, 5, jnp.float32, halo=256)


def test_huge_sparse_operator_falls_back_to_jnp():
    """A pallas-backend op whose x exceeds VMEM still computes (jnp path)."""
    n = 8_000_000
    # don't materialize anything n-sized beyond the band vectors
    op = BandedOperator(jnp.stack([jnp.full((n,), 4.0),
                                   jnp.full((n,), -1.0)]),
                        (0, 1), backend="pallas")
    v = jnp.ones((n,))
    y = op(v)
    assert float(y[0]) == 3.0 and float(y[-1]) == 4.0
