"""s-step (communication-avoiding) GMRES: correctness + round-count."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_sstep, operators, preconditioners
from repro.core.operators import FunctionOperator


@pytest.mark.parametrize("s", [2, 3, 4])
def test_sstep_converges_diagdom(s):
    a = operators.random_diagdom(jax.random.PRNGKey(0), 256)
    b = jax.random.normal(jax.random.PRNGKey(1), (256,))
    res = jax.jit(lambda a, b: gmres_sstep(a, b, s=s, blocks=5,
                                           tol=1e-5))(a, b)
    assert bool(res.converged), (s, float(res.residual))
    rel = float(jnp.linalg.norm(a @ res.x - b) / jnp.linalg.norm(b))
    assert rel < 1e-4


def test_sstep_matches_standard_gmres():
    a = operators.random_diagdom(jax.random.PRNGKey(2), 192)
    b = jax.random.normal(jax.random.PRNGKey(3), (192,))
    r1 = gmres(a, b, m=20, tol=1e-6, max_restarts=50)
    r2 = gmres_sstep(a, b, s=4, blocks=5, tol=1e-6, max_restarts=50)
    assert bool(r2.converged)
    np.testing.assert_allclose(np.asarray(r2.x), np.asarray(r1.x),
                               rtol=1e-2, atol=1e-3)


def test_sstep_preconditioned_convdiff():
    """Monomial-basis conditioning needs a preconditioner on nonnormal
    systems (the classic s-step caveat) — with Neumann(2) it converges."""
    a = operators.convection_diffusion(256, beta=0.4)
    b = jax.random.normal(jax.random.PRNGKey(4), (256,))
    pc = preconditioners.neumann(a, order=2)
    op = FunctionOperator(lambda v: a @ pc(v), 256)
    res = gmres_sstep(op, b, s=4, blocks=5, tol=1e-4, max_restarts=40)
    assert bool(res.converged)
    x = pc(res.x)        # right-preconditioned recovery
    rel = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    assert rel < 5e-4


def test_sstep_degenerate_block_is_safe():
    """Solve converging inside a block must not NaN (CholQR ridge)."""
    a = jnp.diag(jnp.arange(1.0, 65.0))
    b = jnp.zeros((64,)).at[2].set(1.0)      # eigvec: 1-step convergence
    res = gmres_sstep(a, b, s=4, blocks=4, tol=1e-6)
    assert bool(res.converged)
    assert bool(jnp.isfinite(res.x).all())
