"""s-step (communication-avoiding) GMRES: correctness + round-count.

On CPU the block step runs through the Pallas matrix-powers and block-GS
kernels in interpret mode (the default ``kernel_mode()`` dispatch), so
every solve here exercises the real kernel arithmetic; the ``_ref_parity``
tests pin it against the pure-jnp reference path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_sstep, operators, preconditioners, stencils
from repro.core.operators import FunctionOperator


@pytest.mark.parametrize("s", [2, 3, 4])
def test_sstep_converges_diagdom(s):
    a = operators.random_diagdom(jax.random.PRNGKey(0), 256)
    b = jax.random.normal(jax.random.PRNGKey(1), (256,))
    res = jax.jit(lambda a, b: gmres_sstep(a, b, s=s, blocks=5,
                                           tol=1e-5))(a, b)
    assert bool(res.converged), (s, float(res.residual))
    rel = float(jnp.linalg.norm(a @ res.x - b) / jnp.linalg.norm(b))
    assert rel < 1e-4


def test_sstep_matches_standard_gmres():
    a = operators.random_diagdom(jax.random.PRNGKey(2), 192)
    b = jax.random.normal(jax.random.PRNGKey(3), (192,))
    r1 = gmres(a, b, m=20, tol=1e-6, max_restarts=50)
    r2 = gmres_sstep(a, b, s=4, blocks=5, tol=1e-6, max_restarts=50)
    assert bool(r2.converged)
    np.testing.assert_allclose(np.asarray(r2.x), np.asarray(r1.x),
                               rtol=1e-2, atol=1e-3)


def test_sstep_preconditioned_convdiff():
    """Monomial-basis conditioning needs a preconditioner on nonnormal
    systems (the classic s-step caveat) — with Neumann(2) it converges."""
    a = operators.convection_diffusion(256, beta=0.4)
    b = jax.random.normal(jax.random.PRNGKey(4), (256,))
    pc = preconditioners.neumann(a, order=2)
    op = FunctionOperator(lambda v: a @ pc(v), 256)
    res = gmres_sstep(op, b, s=4, blocks=5, tol=1e-4, max_restarts=40)
    assert bool(res.converged)
    x = pc(res.x)        # right-preconditioned recovery
    rel = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    assert rel < 5e-4


def test_sstep_degenerate_block_is_safe():
    """Solve converging inside a block must not NaN (CholQR ridge)."""
    a = jnp.diag(jnp.arange(1.0, 65.0))
    b = jnp.zeros((64,)).at[2].set(1.0)      # eigvec: 1-step convergence
    res = gmres_sstep(a, b, s=4, blocks=4, tol=1e-6)
    assert bool(res.converged)
    assert bool(jnp.isfinite(res.x).all())


# --------------------------------------------------------------------------
# kernel path (matrix_powers + block_gs) on stencil operators
# --------------------------------------------------------------------------
@pytest.mark.parametrize("s", [2, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sstep_stencil_convergence_parity(s, dtype):
    """s-step matches standard gmres on the banded Poisson system, across
    s and band-storage dtypes, through the interpret-mode kernel path."""
    op = stencils.poisson_2d(12, 12, dtype=dtype)
    n = 144
    b = jax.random.normal(jax.random.PRNGKey(0), (n,))
    blocks = max(16 // s, 1)
    res = gmres_sstep(op, b, s=s, blocks=blocks, tol=1e-4, max_restarts=60)
    ref = gmres(op, b, m=s * blocks, tol=1e-4, max_restarts=60)
    assert bool(res.converged), (s, dtype, float(res.residual))
    a_dense = np.asarray(op.todense(), np.float32)
    rel = np.linalg.norm(a_dense @ np.asarray(res.x, np.float32)
                         - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert rel < 5e-4
    np.testing.assert_allclose(np.asarray(res.x, np.float32),
                               np.asarray(ref.x, np.float32),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("make_op", [
    lambda: stencils.poisson_2d(12, 12),
    lambda: stencils.convection_diffusion_2d(10, 12, beta=(0.3, 0.2)),
    lambda: operators.DenseOperator(
        operators.random_diagdom(jax.random.PRNGKey(1), 160)),
])
def test_sstep_kernel_matches_ref_path(make_op, monkeypatch):
    """Kernel-backed block step vs REPRO_KERNELS=ref: identical convergence
    (restart counts within +-1) and matching solutions."""
    op = make_op()
    n = op.shape[0]
    b = jax.random.normal(jax.random.PRNGKey(2), (n,))
    res_k = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60)
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    res_r = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60)
    assert bool(res_k.converged) and bool(res_r.converged)
    assert abs(int(res_k.restarts) - int(res_r.restarts)) <= 1
    np.testing.assert_allclose(np.asarray(res_k.x), np.asarray(res_r.x),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("scale", [1e-6, 1e6])
def test_sstep_scale_invariance(scale):
    """x(c*A, c*b) == x(A, b): the breakdown guards, CholQR ridge and
    Givens happy-probe must all be relative, never absolute floors."""
    op = stencils.poisson_2d(12, 12)
    b = jax.random.normal(jax.random.PRNGKey(5), (144,))
    r1 = gmres_sstep(op, b, s=4, blocks=4, tol=1e-4, max_restarts=60)
    op_s = type(op)(op.bands * scale, op.offsets, op.backend)
    r2 = gmres_sstep(op_s, b * scale, s=4, blocks=4, tol=1e-4,
                     max_restarts=60)
    assert bool(r1.converged) and bool(r2.converged)
    assert abs(int(r1.restarts) - int(r2.restarts)) <= 1
    np.testing.assert_allclose(np.asarray(r2.x), np.asarray(r1.x),
                               rtol=5e-3, atol=5e-4)


def test_sstep_strategy_entry():
    """The strategies table exposes the s-step solver with gmres semantics."""
    from repro.core import strategies

    assert "device_resident_sstep" in strategies.STRATEGIES
    a = operators.random_diagdom(jax.random.PRNGKey(3), 128)
    b = jax.random.normal(jax.random.PRNGKey(4), (128,))
    res = strategies.device_resident_sstep(np.asarray(a), np.asarray(b),
                                           m=16, s=4, tol=1e-5)
    assert bool(res.converged)
    rel = float(jnp.linalg.norm(a @ res.x - b) / jnp.linalg.norm(b))
    assert rel < 1e-4
