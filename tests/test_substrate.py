"""Data pipeline, checkpointing, fault-tolerant runner, elastic policy,

sharding rules — the production substrate.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import abstract_mesh, make_mesh
from repro.checkpoint import checkpoint as ckpt
from repro.data import SyntheticLM, Prefetcher
from repro.runtime import Runner, RunnerConfig, StragglerMonitor, plan
from repro.sharding import partition


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------
def test_data_deterministic():
    p = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4)
    b1 = p.batch(7)
    b2 = p.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_elastic_resharding_exact():
    """The same global stream regardless of host count — the elastic
    restart guarantee."""
    kw = dict(vocab_size=1000, seq_len=8, global_batch=8, seed=3)
    full = SyntheticLM(num_hosts=1, host_id=0, **kw).global_batch_at(5)
    two = SyntheticLM(num_hosts=2, host_id=0, **kw)
    four = SyntheticLM(num_hosts=4, host_id=0, **kw)
    g2 = two.global_batch_at(5)
    g4 = four.global_batch_at(5)
    np.testing.assert_array_equal(full["tokens"], g2["tokens"])
    np.testing.assert_array_equal(g2["tokens"], g4["tokens"])


def test_data_hosts_disjoint():
    kw = dict(vocab_size=1000, seq_len=8, global_batch=8, num_hosts=4, seed=1)
    rows = [SyntheticLM(host_id=h, **kw).batch(0)["tokens"] for h in range(4)]
    flat = np.concatenate([r.reshape(-1, 8) for r in rows])
    assert len(np.unique(flat, axis=0)) == 8   # no duplicated samples


def test_prefetcher():
    p = SyntheticLM(vocab_size=50, seq_len=4, global_batch=2)
    pf = Prefetcher(p, start_step=0)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    pf.close()
    assert (s0, s1) == (0, 1)
    np.testing.assert_array_equal(b0["tokens"], p.batch(0)["tokens"])


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 3, tree, extra={"step": 3})
    restored, manifest = ckpt.restore(d, jax.tree.map(np.zeros_like, tree))
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])
    assert manifest["extra"]["step"] == 3
    assert ckpt.latest_step(d) == 3


def test_checkpoint_corruption_detected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    # flip bytes in the shard
    shard = os.path.join(d, "step_00000001", "shard_00000.npz")
    data = np.load(shard)
    arrays = {k: data[k].copy() for k in data.files}
    arrays["leaf_0"][0, 0] += 999
    np.savez(shard, **arrays)
    with pytest.raises(IOError):
        ckpt.restore(d, _tree())


def test_checkpoint_structure_mismatch(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    with pytest.raises(ValueError):
        ckpt.restore(d, {"x": np.zeros(3)})


def test_checkpoint_async_and_cleanup(tmp_path):
    d = str(tmp_path)
    cp = ckpt.AsyncCheckpointer(d)
    for s in (1, 2, 3, 4, 5):
        cp.save_async(s, _tree(), extra={"step": s})
    cp.wait()
    ckpt.cleanup(d, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(d) == 5


# --------------------------------------------------------------------------
# fault-tolerant runner
# --------------------------------------------------------------------------
def test_runner_recovers_from_injected_failure(tmp_path):
    """Fail at step 7, restore at 5, finish at 12 with correct state."""
    calls = {"failures_left": 1}

    def build_step(mesh):
        def step(state, batch):
            if batch["step"] == 7 and calls["failures_left"] > 0:
                calls["failures_left"] -= 1
                raise RuntimeError("injected device loss")
            return {"x": state["x"] + 1.0}, {"loss": float(state["x"])}
        return step

    runner = Runner(
        config=RunnerConfig(checkpoint_dir=str(tmp_path),
                            checkpoint_every=5, max_failures=2),
        make_mesh=lambda f: f"mesh_after_{f}_failures",
        build_step=build_step,
        init_state=lambda mesh: {"x": jnp.zeros(())},
        batch_for=lambda step, mesh: {"step": step},
    )
    state, step = runner.run(12)
    assert step == 12
    assert runner.failures == 1
    # x counts executed steps; after restore-at-5 it re-runs 5..11
    assert float(state["x"]) == 12.0


def test_straggler_monitor():
    mon = StragglerMonitor(window=20, zscore=3.0, min_samples=5)
    flagged = [mon.record(i, 0.1 + 0.001 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert mon.record(20, 1.5) is True
    assert mon.flagged[0][0] == 20


def test_elastic_plan():
    p = plan(512, model_parallel=16, global_batch=256, want_pods=2)
    assert p.mesh_shape == (2, 16, 16)
    assert p.grad_accum == 1
    # lose a host: 496 devices don't divide -> shrink data axis
    p2 = plan(480, model_parallel=16, global_batch=256, want_pods=2)
    assert p2.mesh_shape[2] == 16
    total = p2.mesh_shape[0] * p2.mesh_shape[1] * p2.mesh_shape[2]
    assert total == 480
    assert p2.global_batch * p2.grad_accum >= 240
    with pytest.raises(ValueError):
        plan(100, model_parallel=16, global_batch=64)


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------
def test_partition_rules():
    mesh = make_mesh((1, 1), ("data", "model"))
    P = jax.sharding.PartitionSpec
    abstract = {
        "embed": jax.ShapeDtypeStruct((1024, 512), jnp.float32),
        "layers": {"attn": {"wq": jax.ShapeDtypeStruct((8, 512, 512),
                                                       jnp.float32)}},
        "norm": jax.ShapeDtypeStruct((512,), jnp.float32),
    }
    sh = partition.param_shardings(mesh, abstract)
    assert sh["embed"].spec == P("model", ("data",))
    assert sh["layers"]["attn"]["wq"].spec == P(None, ("data",), "model")
    assert sh["norm"].spec == P()


def test_moe_expert_sharding_adaptive():
    """EP when E divides the model axis; TP-within-expert otherwise."""
    P = jax.sharding.PartitionSpec
    mesh16 = abstract_mesh((1, 16), ("data", "model"))
    # 128 experts / 16-way: EP on the expert dim
    spec = partition._resolve(mesh16, partition.PARAM_RULES,
                              "layers/moe/w_gate", (24, 128, 512, 1024))
    assert spec == P(None, "model", ("data",), None)
    # 8 experts / 16-way: fall back to TP on the hidden dim (SSPerf h1 iter1)
    spec = partition._resolve(mesh16, partition.PARAM_RULES,
                              "layers/moe/w_gate", (56, 8, 512, 1024))
    assert spec == P(None, None, ("data",), "model")
    spec = partition._resolve(mesh16, partition.PARAM_RULES,
                              "layers/moe/w_down", (56, 8, 1024, 512))
    assert spec == P(None, None, "model", ("data",))


def test_kv_cache_sharding_adaptive():
    """heads over model when divisible; else slots (flash-decoding)."""
    P = jax.sharding.PartitionSpec
    mesh16 = abstract_mesh((1, 16), ("data", "model"))
    spec = partition._resolve(mesh16, partition.CACHE_RULES, "cache/k",
                              (40, 128, 16, 32768, 128), batch_axes="data")
    assert spec == P(None, "data", "model", None, None)
    # 4 kv heads don't divide 16 -> shard the 32768 slots (SSPerf h2 iter1)
    spec = partition._resolve(mesh16, partition.CACHE_RULES, "cache/k",
                              (22, 128, 4, 32768, 64), batch_axes="data")
    assert spec == P(None, "data", None, "model", None)


def test_partition_divisibility_guard():
    mesh = make_mesh((1, 1), ("data", "model"))
    # 12 heads * 64 = 768 divides 1; but a dim of 7 can't shard on 16...
    # simulate with a 16-way mesh via spec resolution only
    spec = partition._resolve(mesh, partition.PARAM_RULES, "attn/wq",
                              (7, 7))
    assert spec == jax.sharding.PartitionSpec(None, None) or \
        spec == jax.sharding.PartitionSpec(("data",), "model")


def test_batch_axes_for():
    mesh = abstract_mesh((2, 2, 1), ("pod", "data", "model"))
    assert partition.batch_axes_for(mesh, 8) == ("pod", "data")
    assert partition.batch_axes_for(mesh, 2) == ("data",)
    assert partition.batch_axes_for(mesh, 1) is None


def test_roofline_collective_parser():
    from repro.roofline import parse_collectives
    hlo = """
      %ag = bf16[128,4096]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}
      %ar = f32[1024]{0} all-reduce(%y), replica_groups=[4,16]<=[64]
      %cp = f32[256]{0} collective-permute(%z)
      %add = f32[2]{0} add(%a, %b)
    """
    ops = parse_collectives(hlo)
    kinds = {o.kind for o in ops}
    assert kinds == {"all-gather", "all-reduce", "collective-permute"}
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.result_bytes == 128 * 4096 * 2
    assert ag.group_size == 4
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.group_size == 16
