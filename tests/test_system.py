"""End-to-end behaviour: real training runs converge; serving generates;

the strategy suite agrees on solutions (the paper's experiment, miniature).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import strategies
from repro.core.operators import random_diagdom


def test_train_e2e_loss_decreases(tmp_path):
    from repro.launch import train as train_cli
    losses = train_cli.main([
        "--arch", "tinyllama-1.1b", "--reduced", "--steps", "30",
        "--batch", "4", "--seq", "64", "--lr", "1e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
    ])
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_resumes_from_checkpoint(tmp_path):
    from repro.launch import train as train_cli
    args = ["--arch", "tinyllama-1.1b", "--reduced", "--steps", "10",
            "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "5"]
    train_cli.main(args)
    from repro.checkpoint import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path)) == 10
    # a second run starts where the first finished (restores step 10)
    losses2 = train_cli.main(args)
    assert losses2 == [] or len(losses2) <= 1   # nothing left to train


def test_serve_e2e(tmp_path):
    from repro.launch import serve as serve_cli
    gen = serve_cli.main(["--arch", "tinyllama-1.1b", "--reduced",
                          "--batch", "2", "--prompt-len", "8",
                          "--gen", "12"])
    assert gen.shape == (12, 2) or gen.shape == (2, 12) or gen.size == 24


def test_strategies_agree_miniature_paper_experiment():
    """All four offload strategies produce the same solution (N=300)."""
    n = 300
    a = np.asarray(random_diagdom(jax.random.PRNGKey(0), n), np.float64)
    b = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (n,)),
                   np.float64)
    xs = {}
    for name in ("serial_numpy", "offload_matvec", "transfer_per_call"):
        x, beta, *_ = strategies.STRATEGIES[name](a, b, m=30, tol=1e-8)
        xs[name] = np.asarray(x)
        assert beta / np.linalg.norm(b) < 1e-7, name
    res = strategies.device_resident(a.astype(np.float32),
                                     b.astype(np.float32), m=30, tol=1e-5)
    xs["device_resident"] = np.asarray(res.x)
    ref = xs["serial_numpy"]
    for name, x in xs.items():
        rtol = 1e-6 if name != "device_resident" else 5e-3
        np.testing.assert_allclose(x, ref, rtol=rtol, atol=1e-4,
                                   err_msg=name)


def test_input_specs_cover_all_cells():
    """input_specs/cache_specs build (abstractly) for every runnable cell."""
    from repro import configs
    from repro.models import (SHAPES, cache_specs, input_specs,
                              shape_applicable)
    n_ok, n_skip = 0, 0
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                n_skip += 1
                assert "full-attention" in why
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs or "token" in specs
            if shape.kind == "decode":
                cache = cache_specs(cfg, shape)
                leaves = jax.tree.leaves(cache)
                assert leaves, (arch, shape.name)
                if cfg.window:
                    slots = leaves[0].shape
                    # ring cache bounded by the window
                    assert max(slots) <= max(cfg.window, 8192), slots
            n_ok += 1
    assert n_ok + n_skip == 40
    assert n_skip == 7   # 7 pure full-attention archs skip long_500k
