"""Persistent autotune cache (kernels/tuning.py).

The ``choose_*`` block pickers are wrapped by ``persistent_choice``: an
in-memory lru_cache backed by an on-disk JSON file so tuned choices
survive process restarts.  Contracts:

  - the env var REPRO_TUNE_CACHE overrides the path; ''/0/off/none
    disables persistence entirely;
  - entries round-trip through JSON (tuples come back as tuples);
  - a disk entry WINS over recomputation (that is the point: a measured
    choice recorded once is honored later), keyed by function, args and
    ambient shard topology;
  - IO failure is non-fatal — the picker still returns a valid choice.
"""
import json
import os

import pytest

from repro.kernels import tuning


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Route the cache to a temp file and leave global state clean."""
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    tuning.clear_tune_cache()
    yield path
    tuning.clear_tune_cache()


def test_cache_path_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", "/some/where/t.json")
    assert tuning.tune_cache_path() == "/some/where/t.json"
    for off in ("", "0", "off", "none", "OFF"):
        monkeypatch.setenv("REPRO_TUNE_CACHE", off)
        assert tuning.tune_cache_path() is None


def test_choice_written_to_disk(tmp_cache):
    val = tuning.choose_gs_block(33, 8192, "float32")
    assert os.path.exists(tmp_cache)
    with open(tmp_cache) as f:
        disk = json.load(f)
    key = [k for k in disk if k.startswith("choose_gs_block|")]
    assert key, disk
    assert disk[key[0]] == val


def test_disk_entry_wins_over_recomputation(tmp_cache):
    """Seed the file with a poisoned value; the lookup must honor it."""
    computed = tuning.choose_matvec_blocks(256, 1024)
    with open(tmp_cache) as f:
        disk = json.load(f)
    (key,) = [k for k in disk if k.startswith("choose_matvec_blocks|")]
    poisoned = [8, 128]
    disk[key] = poisoned
    with open(tmp_cache, "w") as f:
        json.dump(disk, f)
    tuning.clear_tune_cache()            # drop memory; keep the file
    got = tuning.choose_matvec_blocks(256, 1024)
    assert got == tuple(poisoned) != computed


def test_tuple_round_trip_through_json(tmp_cache):
    first = tuning.choose_matvec_blocks(512, 2048)
    assert isinstance(first, tuple)
    tuning.clear_tune_cache()
    again = tuning.choose_matvec_blocks(512, 2048)
    assert again == first and isinstance(again, tuple)


def test_key_includes_topology(tmp_cache):
    tuning.choose_gs_block(17, 4096, "float32")
    with open(tmp_cache) as f:
        disk = json.load(f)
    assert all(f"|p{tuning.shard_size()}" in k for k in disk), disk


def test_disabled_cache_writes_nothing(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", "off")
    tuning.clear_tune_cache()
    try:
        val = tuning.choose_gs_block(33, 4096, "float32")
        assert val > 0
        assert not list(tmp_path.iterdir())
    finally:
        tuning.clear_tune_cache()


def test_unwritable_path_is_non_fatal(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE",
                       "/proc/definitely/not/writable/t.json")
    tuning.clear_tune_cache()
    try:
        assert tuning.choose_gs_block(33, 4096, "float32") > 0
    finally:
        tuning.clear_tune_cache()


def test_clear_disk_removes_file(tmp_cache):
    tuning.choose_gs_block(33, 8192, "float32")
    assert os.path.exists(tmp_cache)
    tuning.clear_tune_cache(disk=True)
    assert not os.path.exists(tmp_cache)


def test_record_tuned_overwrites_model_choice(tmp_cache):
    """Autotune-by-measurement: a recorded timing winner must outrank the
    VMEM-model choice for the same key — now and after a cache reload."""
    modeled = tuning.choose_spmv_block(4096, 9, "float32", k=1)
    measured = 128 if modeled != 128 else 256
    key = tuning.record_tuned(tuning.choose_spmv_block, measured,
                              4096, 9, "float32", k=1)
    assert key.startswith("choose_spmv_block|")
    assert tuning.choose_spmv_block(4096, 9, "float32", k=1) == measured
    # Survives a full in-memory drop (the restart story).
    tuning.clear_tune_cache()
    assert tuning.choose_spmv_block(4096, 9, "float32", k=1) == measured
    # Other keys are untouched by the overwrite.
    assert tuning.choose_spmv_block(4096, 9, "float32", k=4) != measured or \
        tuning.choose_spmv_block.__wrapped__(4096, 9, "float32", k=4) == measured


def test_record_tuned_tuple_values(tmp_cache):
    tuning.record_tuned(tuning.choose_matvec_blocks, (64, 256), 512, 2048)
    got = tuning.choose_matvec_blocks(512, 2048)
    assert got == (64, 256) and isinstance(got, tuple)


def test_record_tuned_rejects_plain_functions():
    with pytest.raises(TypeError):
        tuning.record_tuned(lambda n: n, 128, 64)


def test_gs_payload_fits_gate():
    """The explicit dispatch gate for the single-reduce payload kernel."""
    assert tuning.gs_payload_fits(33, 8192, "float32")
    assert not tuning.gs_payload_fits(33, 8192, "float32", budget=16)
    assert not tuning.gs_payload_fits(33, 0, "float32")
