"""CI perf gate over kernel_bench output.

Diffs a freshly produced kernel_bench JSON (``--smoke`` in CI, the full
suite locally) against the committed ``BENCH_kernels.json`` baseline and
fails on perf-model regressions:

  1. modeled HBM traffic_ratio regressions — the structural ratios are
     deterministic functions of (shape, schedule), so any drift beyond
     ``--tol`` means a kernel's traffic model got worse (or someone edited
     the model without re-baselining);
  2. collective-schedule regressions — per-step psum counts and the
     pipelined inner-loop collective counts must not grow vs baseline;
  3. absolute invariants on the pipelined rows, baseline or not: the
     innermost-loop collective count of the single-reduce pipelined scheme
     must stay >= --min-pipeline-ratio below the split-phase path, at
     residual parity (restarts within +/-1);
  4. absolute invariants on the solver_serve_* rows: the continuous-
     batching server must finish its workload in fewer lockstep cycles
     than the sequential baseline AND within --serve-ideal-slack of the
     lanes x early-retirement ideal (max(ceil(sum r_i / k), max r_i));
  5. absolute invariants on the recovery_* rows: the self-healing
     wrapper's fault-free committed-cycle count (fast path AND stepped
     loop) must stay within 2% of the plain solver's restart count, and
     a solve recovered from an injected NaN must converge within +1
     restart of fault-free — detection/recovery stays off the hot path;
  6. absolute invariants on the precond_restarts_* rows: Chebyshev(>=4)
     and banded ILU(0) must cut restarts >= --precond-restart-factor x
     (default 2) vs unpreconditioned at identical tol on the 2-D Poisson
     and convection-diffusion stencils; the reference line-Jacobi rows
     must merely never be WORSE than unpreconditioned.
  7. absolute invariants on the sliced-ELL rows (hbm_bytes_sell vs
     hbm_bytes_ell): on power-law rows ("powerlaw" in the name) sliced
     ELL must cut modeled SpMV traffic >= --sell-traffic-factor x
     (default 3) below plain ELL; on every other such row (regular
     stencils, where the format degenerates to identity-order ELL) it
     must stay within --sell-stencil-slack (default 1.05x) — the
     never-worse contract that makes "sell" safe as a default.

Rows are matched by name; rows present only on one side are skipped for
diff checks (the smoke subset uses smaller cases than the full run) but
absolute invariants (rule 3) apply to every row that carries the fields.

Exit 0 clean, 1 on any violation (each printed as ``GATE FAIL: ...``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rows_by_name(payload):
    return {r["name"]: r for r in payload.get("rows", [])}


def check(current: dict, baseline: dict | None, *, tol: float,
          min_pipeline_ratio: float,
          serve_ideal_slack: float = 1.1,
          recovery_overhead_slack: float = 1.02,
          precond_restart_factor: float = 2.0,
          sell_traffic_factor: float = 3.0,
          sell_stencil_slack: float = 1.05) -> list[str]:
    fails = []
    cur = _rows_by_name(current)
    base = _rows_by_name(baseline) if baseline else {}

    for name, r in cur.items():
        b = base.get(name)
        if b is not None:
            # 1. modeled traffic ratios are deterministic: drift = regression
            if "traffic_ratio" in r and "traffic_ratio" in b:
                if r["traffic_ratio"] > b["traffic_ratio"] * (1 + tol):
                    fails.append(
                        f"{name}: traffic_ratio {r['traffic_ratio']:.4f} > "
                        f"baseline {b['traffic_ratio']:.4f} (tol {tol:.0%})")
            # 2. collective schedules must not grow
            for key in ("psums_per_step_pipelined", "loop_coll_ops_pipelined",
                        "loop_psums_pipelined"):
                if key in r and key in b and r[key] > b[key]:
                    fails.append(f"{name}: {key} {r[key]} > baseline {b[key]}")

        # 3. absolute invariants — the PR's acceptance metric
        if "loop_coll_ratio" in r:
            if r["loop_coll_ratio"] < min_pipeline_ratio:
                fails.append(
                    f"{name}: loop collective ratio "
                    f"{r['loop_coll_ratio']:.2f}x < required "
                    f"{min_pipeline_ratio:.1f}x "
                    f"(split {r['loop_coll_ops_split']} vs pipelined "
                    f"{r['loop_coll_ops_pipelined']})")
            if abs(r["restarts_split"] - r["restarts_pipelined"]) > 1:
                fails.append(
                    f"{name}: residual parity broken — restarts "
                    f"{r['restarts_split']} (split) vs "
                    f"{r['restarts_pipelined']} (pipelined), must be +/-1")
        if "psums_per_step_pipelined" in r:
            if r["psums_per_step_pipelined"] != 1:
                fails.append(f"{name}: single-reduce scheme must psum once "
                             f"per step, row says "
                             f"{r['psums_per_step_pipelined']}")
        # 4. serving throughput: packed cycles beat sequential, near ideal
        if "cycles_packed" in r:
            packed = r["cycles_packed"]
            seq = r["cycles_sequential"]
            ideal = r["cycles_ideal"]
            if packed >= seq:
                fails.append(
                    f"{name}: packed server used {packed} cycles, no better "
                    f"than {seq} sequential — continuous batching is off")
            if packed > ideal * serve_ideal_slack:
                fails.append(
                    f"{name}: packed {packed} cycles > "
                    f"{serve_ideal_slack:.2f}x ideal {ideal} — lane "
                    f"packing/retirement is leaving cycles on the table")
            if ideal > seq:
                fails.append(f"{name}: cycles_ideal {ideal} > "
                             f"cycles_sequential {seq} — model arithmetic "
                             f"broken")
        # 6. preconditioning: Chebyshev(>=4) and banded ILU(0) must cut
        #    restarts >= precond_restart_factor x on the stencil rows at
        #    identical tol (the acceptance bar).  line_jacobi rows report
        #    but are held only to "never worse" — it is the reference
        #    smoother, not an acceptance vehicle.
        if "restarts_precond" in r and "restarts_unprecond" in r:
            rp, ru = r["restarts_precond"], r["restarts_unprecond"]
            strong = ("chebyshev" in name or "banded_ilu0" in name
                      or "hlo" in name)
            factor = precond_restart_factor if strong else 1.0
            if strong and rp * factor > ru:
                fails.append(
                    f"{name}: preconditioned restarts {rp} not "
                    f">= {factor:.0f}x under unpreconditioned {ru}")
            if not strong and rp > ru:
                fails.append(
                    f"{name}: preconditioned restarts {rp} worse than "
                    f"unpreconditioned {ru}")
        # 7. sliced-ELL vs plain ELL modeled traffic: >= factor x cut on
        #    power-law rows (the format's reason to exist), never worse
        #    than sell_stencil_slack on regular stencils (the safe-default
        #    contract: identity-order degeneration costs ~nothing).
        if "hbm_bytes_sell" in r and "hbm_bytes_ell" in r:
            ratio = r["hbm_bytes_sell"] / r["hbm_bytes_ell"]
            if "powerlaw" in name:
                if ratio * sell_traffic_factor > 1.0:
                    fails.append(
                        f"{name}: sliced-ELL traffic {ratio:.3f}x ELL, "
                        f"needs <= {1 / sell_traffic_factor:.3f}x "
                        f"({sell_traffic_factor:.0f}x cut) on power-law "
                        f"sparsity")
            elif ratio > sell_stencil_slack:
                fails.append(
                    f"{name}: sliced-ELL traffic {ratio:.3f}x ELL on a "
                    f"regular stencil, must stay <= "
                    f"{sell_stencil_slack:.2f}x (never-worse contract)")
        # 5. self-healing: fault-free overhead <= 2%, recovery within +1
        if "overhead_ratio" in r:
            for key in ("overhead_ratio", "stepped_overhead_ratio"):
                if key in r and r[key] > recovery_overhead_slack:
                    fails.append(
                        f"{name}: {key} {r[key]:.4f} > "
                        f"{recovery_overhead_slack:.2f} — self-healing "
                        f"detection is costing cycles on the fault-free "
                        f"path")
            if r.get("recovery_extra_restarts", 0) > 1:
                fails.append(
                    f"{name}: recovered solve took "
                    f"{r['recovery_extra_restarts']} extra restarts "
                    f"({r['restarts_plain']} plain vs "
                    f"{r['restarts_recovered']} recovered), must be <= +1")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh kernel_bench JSON to gate")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "BENCH_kernels.json"),
                    help="committed baseline (default: repo "
                         "BENCH_kernels.json)")
    ap.add_argument("--tol", type=float, default=0.05,
                    help="relative slack on modeled traffic ratios")
    ap.add_argument("--min-pipeline-ratio", type=float, default=2.0,
                    help="required split/pipelined inner-loop collective "
                         "ratio")
    ap.add_argument("--serve-ideal-slack", type=float, default=1.1,
                    help="allowed packed/ideal cycle ratio on "
                         "solver_serve_* rows")
    ap.add_argument("--recovery-overhead-slack", type=float, default=1.02,
                    help="allowed self-healing/plain cycle ratio on "
                         "recovery_* rows (fault-free path)")
    ap.add_argument("--precond-restart-factor", type=float, default=2.0,
                    help="required unprecond/precond restart ratio on the "
                         "precond_restarts_* stencil rows (chebyshev and "
                         "banded_ilu0)")
    ap.add_argument("--sell-traffic-factor", type=float, default=3.0,
                    help="required ELL/sliced-ELL modeled traffic cut on "
                         "power-law sell_spmv_* rows")
    ap.add_argument("--sell-stencil-slack", type=float, default=1.05,
                    help="allowed sliced-ELL/ELL traffic ratio on regular-"
                         "stencil sell_spmv_* rows (never-worse contract)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    baseline = None
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline = json.load(f)
    else:
        print(f"# no baseline at {args.baseline}; absolute checks only")

    fails = check(current, baseline, tol=args.tol,
                  min_pipeline_ratio=args.min_pipeline_ratio,
                  serve_ideal_slack=args.serve_ideal_slack,
                  recovery_overhead_slack=args.recovery_overhead_slack,
                  precond_restart_factor=args.precond_restart_factor,
                  sell_traffic_factor=args.sell_traffic_factor,
                  sell_stencil_slack=args.sell_stencil_slack)
    n = len(current.get("rows", []))
    nb = len(baseline.get("rows", [])) if baseline else 0
    matched = len(set(_rows_by_name(current)) & set(_rows_by_name(baseline))
                  if baseline else ())
    print(f"# bench_gate: {n} rows vs {nb} baseline ({matched} matched)")
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if not fails:
        print("# bench_gate: clean")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
