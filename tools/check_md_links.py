#!/usr/bin/env python
"""Offline markdown link checker for the docs CI job.

Validates, for every markdown file passed on the command line:

  * relative links (``[text](path)`` / ``[text](path#anchor)``) point at
    files that exist in the repo;
  * intra-file anchors (``[text](#section)``) match a heading in the file,
    using GitHub's slugification (lowercase, spaces to dashes, punctuation
    stripped);
  * reference-style definitions (``[label]: target``) get the same checks.

External links (http/https/mailto) are deliberately NOT fetched — the job
must be deterministic and offline — only their syntax is accepted.  Fails
with a per-file report and exit code 1 on any broken link, which is what
keeps README/docs from silently rotting as files move.

    python tools/check_md_links.py README.md ROADMAP.md docs/*.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — skipping images' leading ! is unnecessary (same rules),
# but ignore escaped brackets and in-code spans by a line-level heuristic.
INLINE_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REF_DEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor slug: lowercase, drop punctuation, dash."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)   # linked headings
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans (links there are prose)."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def check_file(path: Path) -> list[str]:
    text = path.read_text(encoding="utf-8")
    anchors = {github_slug(h) for h in HEADING.findall(text)}
    prose = strip_code(text)
    errors = []
    targets = INLINE_LINK.findall(prose) + REF_DEF.findall(prose)
    for target in targets:
        if target.startswith(EXTERNAL):
            continue
        if target.startswith("#"):
            if target[1:] not in anchors:
                errors.append(f"{path}: broken anchor {target!r}")
            continue
        rel, _, anchor = target.partition("#")
        dest = (path.parent / rel).resolve()
        if not dest.exists():
            errors.append(f"{path}: broken link {target!r} "
                          f"(no such file {rel!r})")
        elif anchor and dest.suffix == ".md":
            dest_anchors = {github_slug(h)
                            for h in HEADING.findall(dest.read_text())}
            if anchor not in dest_anchors:
                errors.append(f"{path}: broken anchor {target!r} "
                              f"(not a heading in {rel!r})")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_md_links.py FILE.md [FILE.md ...]")
        return 2
    errors = []
    checked = 0
    for name in argv:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check_file(p))
        checked += 1
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {checked} file(s): "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
