"""CLI shim for the deterministic fault injector.

Validates a ``REPRO_FAULT`` schedule against the registered sites and
execs a command under it — the CI fault-injection matrix leg runs the
serve/recovery suites through this so a typo'd site fails fast instead of
silently injecting nothing:

    python tools/faultinject.py "serve.cycle:1,core.cycle_nan:2" -- \
        python -m pytest tests/test_chaos.py -q

With no command it just validates and prints the parsed schedule
(``--list`` prints the site registry).  The real injector lives at
``src/repro/runtime/faultinject.py`` (tools/ is not importable from the
library path); see docs/robustness.md for the site catalogue.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.runtime import faultinject  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("schedule", nargs="?", default="",
                    help="REPRO_FAULT schedule: site:index[:times],...")
    ap.add_argument("--list", action="store_true",
                    help="print the registered injection sites and exit")
    ap.add_argument("command", nargs=argparse.REMAINDER,
                    help="command to run under the schedule (after --)")
    args = ap.parse_args(argv)

    if args.list:
        for site, doc in sorted(faultinject.SITES.items()):
            print(f"{site:18s} {doc}")
        return 0

    try:
        sched = faultinject.parse_schedule(args.schedule)
    except ValueError as e:
        print(f"faultinject: {e}", file=sys.stderr)
        return 2
    for site, entries in sorted(sched.items()):
        for index, times in entries:
            print(f"armed: {site} at index "
                  f"{'*' if index is None else index} x "
                  f"{'*' if times is None else times}")

    cmd = args.command
    if cmd and cmd[0] == "--":
        cmd = cmd[1:]
    if not cmd:
        return 0
    env = dict(os.environ, REPRO_FAULT=args.schedule)
    return subprocess.call(cmd, env=env)


if __name__ == "__main__":
    sys.exit(main())
